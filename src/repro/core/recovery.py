"""Entropy-Guided Recovery (paper §3.6 — proposed there as future work,
implemented here as a first-class feature).

A per-sequence escalation ladder SR -> WR -> FR -> RR is driven by output
entropy: a *spike* (absolute threshold or relative to an EMA baseline)
escalates one level and applies that level's intervention to the freeze
state; sustained calm de-escalates.  RR (Rewalk Regeneration) cannot be done
inside a jitted step — it rewinds generation — so the step only raises
``rr_request`` and the serving engine performs the rewind (engine.py).

Two freeze granularities share the same ladder (``_ladder_step``):

* ``recovery_update``      — token-granular ``FreezeState`` (contiguous
  engines: slots are individual KV positions).
* ``page_recovery_update`` — page-granular ``PageFreezeState`` (the paged
  engine: slots are whole device pages).  FR additionally raises
  ``thaw_request`` so the host ``PagedController`` remaps stashed pages
  back into the device pool at the lane's next page-boundary tick; RR
  raises ``rr_request`` and the engine performs a page-aware rewind
  (``model.rewind_paged_lane``).

The math is documented in docs/recovery.md.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FreezeConfig
from repro.core.freeze import FreezeState, full_reset, soft_reset, window_reset

# ladder levels
CALM, SR, WR, FR, RR = 0, 1, 2, 3, 4


class RecoveryState(NamedTuple):
    ema_entropy: jnp.ndarray   # (B,) f32 — EMA baseline of output entropy
    level: jnp.ndarray         # (B,) int32 — current escalation level
    calm_steps: jnp.ndarray    # (B,) int32 — consecutive non-spike steps
    steps_seen: jnp.ndarray    # (B,) int32 — for EMA warmup


def init_recovery_state(batch: int) -> RecoveryState:
    return RecoveryState(
        ema_entropy=jnp.zeros((batch,), jnp.float32),
        level=jnp.zeros((batch,), jnp.int32),
        calm_steps=jnp.zeros((batch,), jnp.int32),
        steps_seen=jnp.zeros((batch,), jnp.int32),
    )


def reset_lane(rec: RecoveryState, lane) -> RecoveryState:
    """Lane-granular reset: a retiring request's entropy baseline and
    escalation level must not carry over to the lane's next occupant."""
    sel = jnp.arange(rec.level.shape[0]) == jnp.asarray(lane)
    return RecoveryState(
        ema_entropy=jnp.where(sel, 0.0, rec.ema_entropy),
        level=jnp.where(sel, 0, rec.level),
        calm_steps=jnp.where(sel, 0, rec.calm_steps),
        steps_seen=jnp.where(sel, 0, rec.steps_seen),
    )


def token_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Shannon entropy (nats) of the next-token distribution. logits: (B, V)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def _ladder_step(rec: RecoveryState, logits: jnp.ndarray,
                 cfg: FreezeConfig):
    """Shared per-lane escalation core: spike detection, level bookkeeping
    and the EMA baseline.  Both freeze granularities (token slots and
    device pages) run this exact code so their ladders stay in lockstep —
    the paged-vs-contiguous parity test depends on it.

    Returns (new RecoveryState, spike, level, rr_request)."""
    ent = token_entropy(logits)                                   # (B,)
    # Non-finite entropy (poisoned logits) would otherwise be invisible:
    # NaN comparisons are False, so it never spikes, and once folded into
    # the EMA the baseline is NaN *forever* (every later relative check
    # goes dark).  Treat it as an immediate spike — warmup does not apply,
    # a poisoned lane must not decode 8 steps unchallenged — and hold the
    # EMA at its previous value below.
    bad = ~jnp.isfinite(ent)
    warm = rec.steps_seen >= 8
    spike = bad | (warm & (
        (ent > cfg.entropy_abs_threshold)
        | (ent > cfg.entropy_rel_factor * jnp.maximum(rec.ema_entropy, 1e-3))
    ))
    if not cfg.recovery_enabled:
        spike = jnp.zeros_like(spike)

    level = jnp.where(spike, jnp.minimum(rec.level + 1, RR), rec.level)
    calm = jnp.where(spike, 0, rec.calm_steps + 1)
    deescalate = calm >= cfg.calm_steps_to_deescalate
    level = jnp.where(deescalate & ~spike, jnp.maximum(level - 1, 0), level)
    calm = jnp.where(deescalate, 0, calm)
    rr_request = spike & (level == RR)
    post_level = jnp.where(rr_request, CALM, level)

    # EMA update (only post-update so the spike itself doesn't pollute the
    # baseline immediately)
    a = cfg.entropy_ema_decay
    obs = jnp.where(bad, rec.ema_entropy, ent)   # poison never enters the EMA
    ema = jnp.where(rec.steps_seen == 0, obs, a * rec.ema_entropy + (1 - a) * obs)
    new = RecoveryState(ema_entropy=ema, level=post_level, calm_steps=calm,
                        steps_seen=rec.steps_seen + 1)
    info = {"entropy": ent, "spike": spike, "level": level,
            "rr_request": rr_request,
            # the EMA baseline rides along so the host can compute the
            # thaw-urgency trend (speculative thaw prefetch) without a
            # second fetch
            "ema_entropy": rec.ema_entropy}
    return new, spike, level, info


def recovery_update(
    rec: RecoveryState,
    freeze: FreezeState,            # stacked (L, B, S) or flat (B, S)
    logits: jnp.ndarray,            # (B, V)
    step: jnp.ndarray,
    cfg: FreezeConfig,
) -> Tuple[RecoveryState, FreezeState, dict]:
    new, spike, level, info = _ladder_step(rec, logits, cfg)

    # apply the ladder interventions for sequences spiking at each level
    # (RR is terminal: after requesting a rewalk the escalation restarts
    # from CALM, preventing a rewind livelock under sustained spikes)
    freeze = soft_reset(freeze, spike & (level == SR))
    freeze = window_reset(freeze, spike & (level == WR), step, cfg.recovery_window)
    freeze = full_reset(freeze, spike & (level >= FR))
    return new, freeze, info


# --------------------------------------------------------------------- #
# Page-granular ladder (the paged engine's recovery path)
# --------------------------------------------------------------------- #
def page_recovery_update(
    rec: RecoveryState,
    freeze,                         # PageFreezeState, arrays (L, B, P)
    page_table: jnp.ndarray,        # (L, B, P) global ids, -1 = unmapped
    logits: jnp.ndarray,            # (B, V)
    step: jnp.ndarray,              # (B,) per-lane decode clock
    cfg: FreezeConfig,
) -> Tuple[RecoveryState, "PageFreezeState", dict]:
    """Entropy ladder over page-granular freeze state.  The in-step
    interventions un-freeze *device-resident* pages (they re-enter
    attention on the next step via the kernel's per-page visibility mask);
    bringing *stashed* host pages home cannot happen inside a jitted step,
    so FR additionally raises ``thaw_request`` and the serving engine asks
    the host ``PagedController`` to thaw at the lane's next page-boundary
    tick.  RR raises ``rr_request`` for the engine's page-aware rewind.

    SR:  un-freeze resident pages with d > 1 (the long-frozen ones).
    WR:  un-freeze resident pages frozen within ``recovery_window`` steps.
    FR:  clear the lane's whole page-freeze state + request a host thaw.
    RR:  FR + request a generation rewind (page-granular, engine-side).
    """
    new, spike, level, info = _ladder_step(rec, logits, cfg)
    exists = page_table >= 0
    sel = lambda cond: cond.reshape((1, -1, 1))            # (B,) -> (L,B,P)

    # SR: thaw long-frozen resident pages
    hit = sel(spike & (level == SR)) & exists & (freeze.d > 1)
    # WR: thaw pages frozen in the recovery window (per-lane step clock)
    step_b = jnp.asarray(step, jnp.int32).reshape(1, -1, 1)
    recent = freeze.frozen_at > (step_b - cfg.recovery_window)
    hit = hit | (sel(spike & (level == WR)) & exists & recent)
    # FR / RR: clear everything resident for the lane
    fr = sel(spike & (level >= FR))
    hit = hit | (fr & exists)

    freeze = freeze._replace(
        c=jnp.where(fr, 0, freeze.c),
        d=jnp.where(hit, 0, freeze.d),
        frozen=freeze.frozen & ~hit,
        frozen_at=jnp.where(hit, -1, freeze.frozen_at),
    )
    info["thaw_request"] = spike & (level >= FR)
    return new, freeze, info


def thaw_priority(c, frozen_at):
    """Thaw-candidate score from the freeze counters the schedule already
    tracks per page: pages flagged low-relevance the fewest times (small
    ``c``) and frozen most recently (large ``frozen_at``) are most likely
    to be asked for again, so they thaw first.  The same score, negated,
    ranks eviction victims (coldest page out).  Works on scalars (host
    controller) and arrays alike."""
    return -1000.0 * c + frozen_at


def thaw_urgency(level, entropy, ema_entropy):
    """Priority *trend* score for speculative thaw prefetch: how close a
    lane looks to raising an FR-level ``thaw_request``.

    The ladder escalates one level per spike, and a spike fires when
    entropy exceeds the absolute threshold or ``entropy_rel_factor`` x the
    EMA baseline — so a lane already part-way up the ladder (``level``)
    with entropy running above its baseline is trending toward FR.  The
    serving engine starts copying that lane's top-priority stashed pages
    (ranked by :func:`thaw_priority`) into device staging slots *before*
    the request fires, turning the eventual thaw into a page-table remap
    instead of a blocking host->device upload.

    Returns ``level + max(relative-entropy-excess, 0)`` — higher means
    closer to FR.  The engine currently stages lanes whose score is
    ``>= WR`` (within one spike of FR) plus any lane with a thaw already
    pending; looser gates buy little and cost a dispatch per staged page
    (``PagedContinuousEngine._maybe_prefetch``).  Works on scalars and
    numpy arrays alike (host-side, consumed from the telemetry ring).
    """
    import numpy as np
    rel = (np.asarray(entropy, np.float32)
           - np.asarray(ema_entropy, np.float32)) \
        / np.maximum(np.asarray(ema_entropy, np.float32), 1e-3)
    return np.asarray(level, np.float32) + np.maximum(rel, 0.0)

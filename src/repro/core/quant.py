"""Per-page KV quantization for frozen / host-stashed pages.

The soft-freeze invariant — a frozen page receives no KV writes — makes
frozen pages safe lossy-compression victims: their bytes are immutable
until a thaw/rewind makes them hot again, so a one-shot symmetric
quantization at freeze time never has to track in-place updates.  This
module owns the numeric recipe; `core.paging.PagedController` /
`core.cache.HostOffloadController` decide *when* a page is quantized and
`kernels/paged_decode_attn.py` dequantizes on the fly at attention time.

Layout (one page of K or V has shape ``(page, KVH, hd)``):

* **scales** — per-page, per-kv-head symmetric scales, shape ``(KVH,)``
  float32: ``scale_h = amax(|page[:, h, :]|) / qmax``.  Per-head because
  K/V magnitudes vary far more across heads than across the positions of
  one page; per-page because pages are the freeze/stash/thaw granule.
  An all-zero head gets ``scale = 1.0`` (payload zeros, dequant exact).
* **int8 payload** — ``clip(rint(x / scale), -127, 127)``, 1 byte/elem,
  ``qmax = 127``.  Round-trip error is bounded elementwise by
  ``scale / 2`` (one half quantization step).
* **fp8 payload** (``float8_e4m3fn`` via ``ml_dtypes``, gated — never a
  new dependency; jax already ships ml_dtypes) — ``x / scale`` cast to
  e4m3, ``qmax = 448`` (the e4m3 finite max, so the head's amax lands on
  a representable value).  Relative error ≤ 2**-4 (half ulp of a 3-bit
  mantissa) plus a ``scale * 2**-10`` subnormal floor near zero.

Device pools keep ONE dtype: a quantized page stored in the pool holds
the *integer-valued payload cast into the pool dtype* (int8 values are
exact in bf16/f32; e4m3 values are exact in bf16 and f32), with the
page's scales carried next to the page table.  The kernel multiplies by
``scale`` only where the per-page quant flag is set — hot pages multiply
by nothing at all, which is what keeps ``kv_quant="none"`` bit-identical
to the unquantized engine.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

try:  # ml_dtypes ships with jax; gated anyway per repo dependency policy
    from ml_dtypes import float8_e4m3fn as _FP8
except ImportError:                              # pragma: no cover
    _FP8 = None

# per-page quant flag values, as stored next to the page table
QUANT_NONE, QUANT_INT8, QUANT_FP8 = 0, 1, 2
MODES = {"none": QUANT_NONE, "int8": QUANT_INT8, "fp8": QUANT_FP8}
_QMAX = {QUANT_INT8: 127.0, QUANT_FP8: 448.0}


def fp8_supported() -> bool:
    return _FP8 is not None


def resolve_mode(kv_quant: str) -> int:
    """Map a ``--kv-quant`` string to its flag value, validating support."""
    if kv_quant not in MODES:
        raise ValueError(f"kv_quant must be one of {sorted(MODES)}, "
                         f"got {kv_quant!r}")
    if kv_quant == "fp8" and not fp8_supported():
        raise ValueError("kv_quant='fp8' needs ml_dtypes.float8_e4m3fn, "
                         "which this environment does not provide")
    return MODES[kv_quant]


def page_scales(page: np.ndarray, mode: int) -> np.ndarray:
    """Per-kv-head symmetric scales for one ``(page, KVH, hd)`` page."""
    amax = np.max(np.abs(page.astype(np.float32)), axis=(0, 2))
    scales = amax / _QMAX[mode]
    return np.where(amax > 0, scales, 1.0).astype(np.float32)


def quantize_page(page: np.ndarray, mode: int,
                  scales: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize one page to its 1-byte payload.

    Returns ``(payload, scales)``; payload dtype is int8 (mode int8) or
    float8_e4m3fn (mode fp8) — 1 byte/elem either way, which is what the
    host-stash byte gauges count.  Pass precomputed ``scales`` to reuse a
    page's stored scales instead of re-deriving them from the data; on
    values already on that grid (a dequantized payload) the result is
    byte-identical to the original payload, so repeated cycles never
    compound error.  Note the input is always REAL page values — to
    narrow an integer-valued payload held in a pool dtype back to bytes,
    use ``narrow_payload`` (dividing a payload by its scales here would
    silently re-quantize it).
    """
    if scales is None:
        scales = page_scales(page, mode)
    x = page.astype(np.float32) / scales[None, :, None]
    if mode == QUANT_INT8:
        payload = np.clip(np.rint(x), -127, 127).astype(np.int8)
    elif mode == QUANT_FP8:
        if _FP8 is None:
            raise RuntimeError("fp8 payload requested without ml_dtypes")
        payload = x.astype(_FP8)
    else:
        raise ValueError(f"not a quantized mode: {mode}")
    return payload, scales


def narrow_payload(page: np.ndarray, mode: int) -> np.ndarray:
    """Cast an already-quantized pool-dtype page back to its 1-byte store
    dtype.  The values are already on the quantization grid (the pool holds
    the integer-valued payload — see module docstring), so this is a pure
    width change: no rounding, no re-derived scales, and in particular no
    double quantization (the property tests pin this)."""
    if mode == QUANT_INT8:
        return np.asarray(page, np.float32).astype(np.int8)
    if mode == QUANT_FP8:
        if _FP8 is None:
            raise RuntimeError("fp8 payload requested without ml_dtypes")
        return np.asarray(page, np.float32).astype(_FP8)
    raise ValueError(f"not a quantized mode: {mode}")


def dequantize_page(payload: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Exact inverse of the payload representation: f32 page values."""
    return payload.astype(np.float32) * scales[None, :, None].astype(
        np.float32)


def roundtrip_bound(page: np.ndarray, mode: int,
                    scales: Optional[np.ndarray] = None) -> np.ndarray:
    """Elementwise error bound ``|x - dq(q(x))|`` must satisfy — the
    documented envelope the property tests assert (docs/quantization.md).
    """
    if scales is None:
        scales = page_scales(page, mode)
    s = scales[None, :, None].astype(np.float32)
    if mode == QUANT_INT8:
        return np.broadcast_to(s / 2.0, page.shape)
    # e4m3: half-ulp relative error + a subnormal absolute floor
    return np.abs(page.astype(np.float32)) * 2.0**-4 + s * 2.0**-10

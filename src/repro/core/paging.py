"""Bounded-active paged KV serving — the TPU-native ASR-KF-EGR layout for
very long contexts (long_500k).

Device holds at most P physical pages per sequence; the page table maps each
physical slot to a global page id.  Freeze bookkeeping (c, d, frozen,
frozen_at) runs at *page* granularity inside the jitted step, using the same
sublinear schedule (Eq. 3) over page-level relevance (masked mean of the
Eq. 2 token scores).  The host `PagedController` performs the actual
swap-in/swap-out between steps: frozen pages are released to the host store,
expired pages are re-pinned into free slots — batched, page-granular DMA,
exactly the "batched transfers" the paper calls for in §6.

Bounded-memory guarantee (beyond-paper): when the active pool is full and no
page is naturally freezable, the lowest-relevance out-of-window page is
force-frozen (with the schedule's d for its counter) so device memory never
exceeds P pages.  The paper lets the active set float; the bound is what
makes 500k-token decode lowerable on a fixed HBM budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core.freeze import schedule


class PageFreezeState(NamedTuple):
    """Freeze bookkeeping per *global* page id (host-managed, device-visible
    slice passed per step). Arrays are (B, P) over physical slots."""
    c: jnp.ndarray
    d: jnp.ndarray
    frozen: jnp.ndarray
    frozen_at: jnp.ndarray


def init_page_freeze_state(batch: int, pages: int) -> PageFreezeState:
    return PageFreezeState(
        c=jnp.zeros((batch, pages), jnp.int32),
        d=jnp.zeros((batch, pages), jnp.int32),
        frozen=jnp.zeros((batch, pages), bool),
        frozen_at=jnp.full((batch, pages), -1, jnp.int32),
    )


def paged_decode_attention(
    q: jnp.ndarray,           # (B, H, hd)
    k_pages: jnp.ndarray,     # (B, P, page, KVH, hd)
    v_pages: jnp.ndarray,     # (B, P, page, KVH, hd)
    slot_mask: jnp.ndarray,   # (B, P, page) bool
    page_table: Optional[jnp.ndarray] = None,   # (B, P); slots < 0 unmapped
    page_visible: Optional[jnp.ndarray] = None, # (B, P) bool; False = frozen
    page_quant: Optional[jnp.ndarray] = None,   # (B, P) i32; != 0 = quantized
    kv_scales: Optional[jnp.ndarray] = None,    # (B, P, 2, KVH) f32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode attention over the active page pool.

    Returns (out (B, H, hd), page_relevance (B, P)) where page relevance is
    the masked mean over the page's slots of the Eq. 2 token score.
    Unmapped slots (page_table < 0) are excluded regardless of slot_mask —
    the reference semantics of the Pallas kernel's page-table skip.
    ``page_visible`` is the thaw-aware visibility mask (``~frozen`` after
    the recovery ladder ran): invisible pages contribute nothing and report
    relevance 0, exactly like an unmapped slot, while a page the ladder
    just thawed re-enters both the softmax and the relevance accounting.
    ``page_quant`` / ``kv_scales`` are the per-page quantization slots
    (core/quant.py): pages whose flag is non-zero hold an integer-valued
    payload and are dequantized here by their per-kv-head scales — K by
    ``kv_scales[..., 0, :]``, V by ``kv_scales[..., 1, :]`` — before the
    relevance and softmax einsums, so the freeze schedule scores real
    magnitudes.  Unflagged pages keep their exact bytes (the dequant is a
    masked select, not a multiply by 1.0), which is what keeps
    ``kv_quant="none"`` bit-identical to the unquantized path.
    """
    B, H, hd = q.shape
    _, P, page, KVH, _ = k_pages.shape
    if page_table is not None:
        slot_mask = slot_mask & (page_table >= 0)[..., None]
    if page_visible is not None:
        slot_mask = slot_mask & page_visible[..., None]
    G = H // KVH
    qf = q.reshape(B, KVH, G, hd).astype(jnp.float32)
    kf = k_pages.astype(jnp.float32)
    vf_pages = v_pages.astype(jnp.float32)
    if page_quant is not None and kv_scales is not None:
        flag = (page_quant != 0)[:, :, None, None, None]   # (B,P,1,1,1)
        sc = kv_scales.astype(jnp.float32)
        sk = sc[:, :, 0][:, :, None, :, None]              # (B,P,1,KVH,1)
        sv = sc[:, :, 1][:, :, None, :, None]
        kf = jnp.where(flag, kf * sk, kf)
        vf_pages = jnp.where(flag, vf_pages * sv, vf_pages)
    raw = jnp.einsum("bkgh,bpskh->bkgps", qf, kf)              # (B,KVH,G,P,page)
    tok_rel = jnp.mean(jnp.abs(raw), axis=(1, 2))              # (B,P,page)
    denom = jnp.maximum(jnp.sum(slot_mask, axis=-1), 1)
    page_rel = jnp.sum(tok_rel * slot_mask, axis=-1) / denom   # (B,P)

    s = raw / math.sqrt(hd)
    s = jnp.where(slot_mask[:, None, None, :, :], s, -1e30)
    s = s.reshape(B, KVH, G, P * page)
    p = jax.nn.softmax(s, axis=-1)
    any_active = jnp.any(slot_mask.reshape(B, 1, 1, -1), axis=-1, keepdims=True)
    p = jnp.where(any_active, p, 0.0)
    vf = vf_pages.reshape(B, P * page, KVH, hd)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vf)
    return out.reshape(B, H, hd).astype(q.dtype), page_rel


def write_tail(
    k_pages: jnp.ndarray, v_pages: jnp.ndarray, slot_mask: jnp.ndarray,
    new_k: jnp.ndarray, new_v: jnp.ndarray,
    tail_slot: jnp.ndarray,   # () or (B,) int32 physical slot of the tail page
    tail_off: jnp.ndarray,    # () or (B,) int32 offset within the tail page
    live: Optional[jnp.ndarray] = None,   # (B,) bool; False lanes skip write
):
    """Append one token's (K, V) (B, KVH, hd) into each lane's tail page.

    `tail_slot` / `tail_off` may be per-lane (B,) vectors — continuous
    batching runs every lane at its own position, so lanes sit at different
    offsets of different physical slots.  `live=False` lanes (idle /
    mid-admission) leave their pool untouched."""
    B = new_k.shape[0]
    P, page = k_pages.shape[1], k_pages.shape[2]
    ts = jnp.broadcast_to(jnp.asarray(tail_slot, jnp.int32), (B,))
    to = jnp.broadcast_to(jnp.asarray(tail_off, jnp.int32), (B,))
    onehot_p = jax.nn.one_hot(ts, P, dtype=bool)            # (B, P)
    onehot_s = jax.nn.one_hot(to, page, dtype=bool)         # (B, page)
    sel = onehot_p[:, :, None] & onehot_s[:, None, :]       # (B, P, page)
    if live is not None:
        sel = sel & live[:, None, None]
    selx = sel[:, :, :, None, None]
    k_pages = jnp.where(selx, new_k[:, None, None], k_pages)
    v_pages = jnp.where(selx, new_v[:, None, None], v_pages)
    slot_mask = slot_mask | sel
    return k_pages, v_pages, slot_mask


def page_freeze_update(
    state: PageFreezeState,
    page_rel: jnp.ndarray,     # (B, P)
    page_table: jnp.ndarray,   # (B, P) global ids, -1 = empty
    current_page: jnp.ndarray, # () or (B,) int32 — global id of the tail page
    step: jnp.ndarray,         # () or (B,) int32 — per-lane decode clock
    cfg: FreezeConfig,
    reserved_slots: int = 0,
) -> Tuple[PageFreezeState, Dict[str, jnp.ndarray]]:
    """Page-granular Alg. 1 with the sliding window expressed in pages and
    the forced-freeze bound when the pool is saturated.

    `current_page` / `step` may be per-lane (B,) vectors — continuous
    batching runs every lane at its own tail page and decode-step clock.

    ``reserved_slots`` (static) is the number of physical slots per lane
    the host keeps out of the allocator — the speculative-thaw staging
    slots of the async DMA pipeline.  They are permanently unmapped from
    this function's point of view, so they are subtracted from the free
    count before the forced-freeze headroom check: a pool of P + S slots
    with S reserved behaves *identically* to a plain P-slot pool (the
    async-vs-sync token-parity guarantee of serving/engine.py)."""
    window_pages = max(1, -(-cfg.window // cfg.page_size))
    current_page = jnp.asarray(current_page, jnp.int32)
    cp_b = current_page[:, None] if current_page.ndim else current_page
    step = jnp.asarray(step, jnp.int32)
    step_b = step[:, None] if step.ndim else step
    exists = page_table >= 0
    in_window = page_table > (cp_b - window_pages)
    was_frozen = state.frozen

    from repro.core.freeze import effective_tau
    eligible = exists & ~in_window & ~was_frozen
    flagged = eligible & (page_rel < effective_tau(page_rel, eligible, cfg))
    c_new = state.c + flagged.astype(jnp.int32)
    d_sched = schedule(c_new, cfg.k_soft)
    just_frozen = flagged & (d_sched > 0)

    # --- forced freeze when pool is (nearly) full: lowest-relevance page --- #
    # headroom of 2: one slot for the next tail page, one so a long-lived
    # (d >= page_size) forced-frozen page is always available for the host
    # controller's swap-out at its page-cadence tick (organic freezes have
    # short timers and can churn back between ticks)
    durable_frozen = jnp.sum((was_frozen | just_frozen) &
                             (jnp.where(just_frozen, d_sched, state.d) >=
                              cfg.page_size), axis=-1)
    free_after = jnp.sum(~exists, axis=-1) - reserved_slots + durable_frozen
    need_force = free_after < 2
    cand = jnp.where(eligible & ~just_frozen, page_rel, jnp.inf)
    forced_idx = jnp.argmin(cand, axis=-1)                      # (B,)
    can_force = jnp.isfinite(jnp.min(cand, axis=-1))
    force = (need_force & can_force)[:, None] & (
        jax.nn.one_hot(forced_idx, page_rel.shape[1], dtype=bool))
    c_new = c_new + force.astype(jnp.int32)
    just_frozen = just_frozen | force
    # forced evictions persist at least one page-fill interval so the host
    # controller (which runs at page-allocation cadence) can offload them
    # before the rolling decrement would restore them
    d_forced = jnp.maximum(schedule(c_new, cfg.k_soft), cfg.page_size)
    d_sched = jnp.where(force, d_forced, d_sched)

    frozen_mid = was_frozen | just_frozen
    d_mid = jnp.where(just_frozen, d_sched, state.d)
    frozen_at = jnp.where(just_frozen, step_b, state.frozen_at)

    d_dec = jnp.where(was_frozen, d_mid - 1, d_mid)
    restored = was_frozen & (d_dec <= 0)
    frozen_new = frozen_mid & ~restored
    d_new = jnp.where(restored, 0, d_dec)
    decay = (step_b % cfg.history) == (cfg.history - 1)
    c_new = jnp.where(decay, jnp.maximum(c_new - 1, 0), c_new)

    new = PageFreezeState(c=c_new, d=d_new, frozen=frozen_new, frozen_at=frozen_at)
    info = {"just_frozen": just_frozen, "restored": restored,
            "n_frozen": jnp.sum(frozen_new & exists, axis=-1)}
    return new, info


# ===================================================================== #
# Host-side paging controller (runs between jitted steps)
# ===================================================================== #
@dataclasses.dataclass
class PagedController:
    """Source-of-truth host store of every completed page + the device pool
    management: evict frozen pages, re-pin restored pages, allocate the tail.

    Works on ONE attention layer's pool (engine keeps one per layer) or on
    stacked (L, ...) arrays — all ops are numpy, page-batched.
    """
    cfg: ModelConfig
    batch: int
    max_active_pages: int
    # host store: key (layer, b, global_page) -> (k, v) numpy (page, KVH, hd)
    store: Dict[Tuple[int, int, int], Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    # freeze bookkeeping for *offloaded* pages: key -> dict(c, d, frozen_at)
    frozen_meta: Dict[Tuple[int, int, int], Dict[str, int]] = \
        dataclasses.field(default_factory=dict)
    n_swap_out: int = 0
    n_swap_in: int = 0
    n_thaw: int = 0        # entropy-guided recovery: pages remapped early
    # ---- speculative-thaw staging (async DMA pipeline) ---------------- #
    # Fixed reserved physical slots per (layer, lane): the engine keeps
    # them out of every allocator below and uploads likely-thaw pages into
    # them between ticks.  `staged_keys` maps a stashed page key to the
    # staging slot already holding its K/V on device: installing it then
    # skips the host->device upload — metadata points at the target slot
    # and the engine issues a device-side copy staging-slot -> target slot
    # (`pending_remaps`) after the metadata push.  The target slot is
    # chosen by the SAME free/evict logic as the upload path, so the pool
    # layout — and with it every float summation order downstream — is
    # identical whether or not a page was staged (exact async-vs-sync
    # token parity).  The engine owns both structures; the controller
    # only consumes them.
    stage_slots: Dict[Tuple[int, int], list] = \
        dataclasses.field(default_factory=dict)
    staged_keys: Dict[Tuple[int, int, int], int] = \
        dataclasses.field(default_factory=dict)
    pending_remaps: list = dataclasses.field(default_factory=list)
    n_upload_installs: int = 0   # installs that crossed the host bus
    n_remap_installs: int = 0    # installs served from a staging slot
    n_thaw_upload: int = 0       # thaw-path installs that needed an upload
    n_thaw_remap: int = 0        # thaw-path installs that were remap-only
    kv_dirty: bool = False       # this tick wrote pool K/V (push needs it)
    # ---- host-stash memory budget (robustness) ------------------------ #
    # Every byte entering/leaving ``store`` goes through ``_store_put`` /
    # ``_store_pop`` so ``stash_bytes`` is exact by construction
    # (``host_bytes()`` recomputes it from scratch as the auditor's ground
    # truth).  ``exported_bytes`` tracks pages a suspended lane carried
    # out via ``export_lane`` — they left the stash but still exist on the
    # host (a LaneSnapshot), so leak detection needs both gauges.
    # ``stash_budget_bytes`` (None = unbounded) feeds the engine's
    # graceful-degradation ladder AND hard-stops the tick's swap-out rung
    # at the ceiling (``n_denied_offloads`` — the page stays resident and
    # frozen).  Correctness-critical stash writers (overflow stash at
    # install, forced eviction for headroom, suspend/export) are exempt:
    # they must not fail because an optimization filled the stash, so a
    # workload that *requires* stashing can exceed the budget — the
    # ladder's throttle/shed rungs exist to keep it from getting there.
    stash_bytes: int = 0
    exported_bytes: int = 0
    stash_budget_bytes: Optional[int] = None
    # optional faults.Endpoint guarding NEW stash allocations (the
    # "stash" injection point); wired by the engine under chaos
    stash_endpoint: Optional[object] = None
    n_ticks: int = 0             # boundary ticks observed (deepen cadence)
    # ladder stage 2: skip every other offloaded-timer decrement, halving
    # the rate stashed pages come home while host memory is pressured
    deepen_timers: bool = False
    n_deepen_skips: int = 0
    n_stash_faults: int = 0      # swap-outs skipped by injected alloc fails
    n_trims: int = 0             # redundant resident copies freed (stage 1)
    n_denied_offloads: int = 0   # swap-outs denied by the budget ceiling
    # ---- per-page KV quantization (core/quant.py) --------------------- #
    # ``kv_quant`` != "none" quantizes exactly the frozen / stashed pages:
    # resident frozen pages are quantized in place at the boundary tick
    # (integer payload in the pool dtype + per-page per-kv-head scales in
    # the pool's ``page_quant`` / ``kv_scales`` slots — the kernel dequants
    # at attention time), and every store payload is the 1-byte narrow
    # form.  ``quant_meta`` carries each stashed page's (K scales,
    # V scales) parallel to ``store`` — store values stay (k, v) 2-tuples
    # so the byte-gauge invariant (stash_bytes == Σ nbytes) is unchanged.
    # A thaw installs the *quantized* payload and its scales (no host
    # dequant round-trip); only ``ensure_resident`` — the rewind path,
    # whose tail page must be writable — dequantizes host-side.
    kv_quant: str = "none"
    quant_meta: Dict[Tuple[int, int, int],
                     Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    # lane id -> device bytes saved by packed (1-byte) resident quantized
    # pages — the engine's kv_device_bytes gauge subtracts this (on real
    # TPU the frozen region of the pool is physically int8/fp8; the CPU
    # model widens payloads into the one-dtype pool, so the ledger models
    # the packed layout)
    resident_quant: Dict[int, int] = dataclasses.field(default_factory=dict)
    n_quantized_pages: int = 0   # pages quantized fresh (in-place pass,
    #                              swap-out narrowing, admission stash)

    # ---- single entry/exit points for host-stash bytes ---------------- #
    def _store_put(self, key: Tuple[int, int, int],
                   kv: Tuple[np.ndarray, np.ndarray],
                   guarded: bool = True) -> None:
        """The only writer of ``store``.  Keeps ``stash_bytes`` exact
        (overwrites are re-counted, not double-counted) and runs NEW
        allocations through the ``stash`` fault endpoint — an injected
        allocation failure raises ``StashAllocError`` for the caller to
        degrade on.  ``guarded=False`` bypasses injection for paths that
        must not fail (resume import: the bytes already exist)."""
        old = self.store.get(key)
        if old is not None:
            self.stash_bytes -= old[0].nbytes + old[1].nbytes
        elif guarded and self.stash_endpoint is not None:
            from repro.serving.faults import Endpoint, StashAllocError
            if self.stash_endpoint.call(lambda: True) is Endpoint.FAILED:
                self.n_stash_faults += 1
                raise StashAllocError(
                    "stash", f"host-stash allocation failed for page {key}")
        self.store[key] = kv
        self.stash_bytes += kv[0].nbytes + kv[1].nbytes

    def _store_pop(self, key: Tuple[int, int, int]
                   ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """The only remover of ``store``; see ``_store_put``.  The page's
        quant scales (``quant_meta``) live and die with its store entry."""
        kv = self.store.pop(key, None)
        if kv is not None:
            self.stash_bytes -= kv[0].nbytes + kv[1].nbytes
            self.quant_meta.pop(key, None)
        return kv

    # ---- per-page quantization plumbing ------------------------------- #
    @property
    def quant_mode(self) -> int:
        from repro.core import quant
        return quant.MODES[self.kv_quant]

    @property
    def device_savings_bytes(self) -> int:
        """Device bytes saved by packed resident quantized pages (the
        engine's kv_device_bytes gauge subtracts this; 0 under
        ``kv_quant="none"`` so the gauge is exactly the physical pool)."""
        return sum(self.resident_quant.values())

    def _store_payload(self, pool: dict, l: int, b: int, p: int
                       ) -> Tuple[Tuple[np.ndarray, np.ndarray],
                                  Optional[Tuple[np.ndarray, np.ndarray]]]:
        """The (k, v) bytes a swap-out/eviction of pool slot ``(l, b, p)``
        should place in the host store, plus the page's quant scales (None
        when full precision).  An already-quantized pool page narrows to
        its 1-byte payload with its EXISTING scales — never re-quantized;
        an unquantized page under an active quant mode is quantized fresh
        (the freeze-time quantization for pools the in-place pass has not
        seen, e.g. direct-tick callers without quant slots)."""
        from repro.core import quant
        k_page = np.asarray(pool["k"][l, b, p])
        v_page = np.asarray(pool["v"][l, b, p])
        mode = self.quant_mode
        if not mode:
            return (k_page.copy(), v_page.copy()), None
        pq = pool.get("page_quant")
        if pq is not None and pq[l, b, p]:
            sc = pool["kv_scales"]
            return ((quant.narrow_payload(k_page, int(pq[l, b, p])),
                     quant.narrow_payload(v_page, int(pq[l, b, p]))),
                    (np.array(sc[l, b, p, 0], np.float32),
                     np.array(sc[l, b, p, 1], np.float32)))
        pk, sk = quant.quantize_page(k_page, mode)
        pv, sv = quant.quantize_page(v_page, mode)
        self.n_quantized_pages += 1
        return (pk, pv), (sk, sv)

    def _clear_quant_slot(self, pool: dict, l: int, b: int, p: int) -> None:
        if "page_quant" in pool:
            pool["page_quant"][l, b, p] = 0
            pool["kv_scales"][l, b, p] = 1.0

    def _install_kv(self, pool: dict, l: int, b: int, p: int,
                    key: Tuple[int, int, int]) -> None:
        """Write a store payload into pool slot ``(l, b, p)``: a quantized
        payload installs AS-IS (1-byte values widened into the pool dtype)
        with its scales in the pool's quant slots — the kernel dequants at
        attention time, no host round-trip; pools without quant slots
        (direct-tick tests) get the host-side dequantized page instead."""
        from repro.core import quant
        kk, vv = self.store[key]
        qm = self.quant_meta.get(key)
        if qm is None:
            pool["k"][l, b, p] = kk
            pool["v"][l, b, p] = vv
            self._clear_quant_slot(pool, l, b, p)
        elif "page_quant" in pool:
            pool["k"][l, b, p] = kk
            pool["v"][l, b, p] = vv
            pool["page_quant"][l, b, p] = self.quant_mode
            pool["kv_scales"][l, b, p, 0] = qm[0]
            pool["kv_scales"][l, b, p, 1] = qm[1]
        else:
            pool["k"][l, b, p] = quant.dequantize_page(kk, qm[0])
            pool["v"][l, b, p] = quant.dequantize_page(vv, qm[1])

    def _quantize_frozen_resident(self, pool: dict, fstate: dict,
                                  lane_set) -> None:
        """Quantize every resident frozen page of ``lane_set`` in place —
        the device-residency arm of the byte cut.  Frozen pages receive no
        KV writes (the soft-freeze invariant), so the payload is immutable
        until a thaw/rewind; pages already flagged are skipped (the
        no-double-quantization guarantee)."""
        from repro.core import quant
        mode = self.quant_mode
        if not mode or "page_quant" not in pool:
            return
        k, v, pt = pool["k"], pool["v"], pool["page_table"]
        pq, sc = pool["page_quant"], pool["kv_scales"]
        frozen = fstate["frozen"]
        L, _, P = pt.shape
        wrote = False
        for l in range(L):
            for b in lane_set:
                for p in range(P):
                    if pt[l, b, p] < 0 or not frozen[l, b, p] \
                            or pq[l, b, p]:
                        continue
                    pk, skl = quant.quantize_page(np.asarray(k[l, b, p]),
                                                  mode)
                    pv, svl = quant.quantize_page(np.asarray(v[l, b, p]),
                                                  mode)
                    k[l, b, p] = pk
                    v[l, b, p] = pv
                    pq[l, b, p] = mode
                    sc[l, b, p, 0] = skl
                    sc[l, b, p, 1] = svl
                    self.n_quantized_pages += 1
                    wrote = True
        if wrote:
            self.kv_dirty = True

    def refresh_resident_quant(self, pool: dict, b: int,
                               lane_id: int) -> None:
        """Rebuild one lane's packed-residency ledger from its pulled pool
        slice: mapped pages whose quant flag is set occupy 1 byte/elem on a
        real mixed-precision pool, so the difference to the full-dtype
        width is credited to ``device_savings_bytes``."""
        pq = pool.get("page_quant")
        if pq is None or not self.quant_mode:
            self.resident_quant.pop(lane_id, None)
            return
        pt, k = pool["page_table"], pool["k"]
        n = int(((pq[:, b] != 0) & (pt[:, b] >= 0)).sum())
        page_elems = int(np.prod(k.shape[3:]))
        saved = n * page_elems * (np.dtype(k.dtype).itemsize - 1) * 2
        if saved:
            self.resident_quant[lane_id] = saved
        else:
            self.resident_quant.pop(lane_id, None)

    @property
    def stash_pressure(self) -> float:
        """Measured stash bytes as a fraction of the budget (0.0 when
        unbounded) — the engine's degradation-ladder input."""
        if not self.stash_budget_bytes:
            return 0.0
        return self.stash_bytes / self.stash_budget_bytes

    def trim_resident_copies(self, lane: Optional[int] = None) -> int:
        """Degradation-ladder stage 1: free the host copies of
        device-resident pages (store entries with no ``frozen_meta``).
        They are a read-back optimization — kept so re-freezing a page
        skips nothing, and exported wholesale on suspend — but the
        swap-out path unconditionally re-copies from the pulled pool, so
        dropping them is always safe.  Returns bytes freed."""
        keys = [k for k in self.store if k not in self.frozen_meta
                and (lane is None or k[1] == lane)]
        freed = 0
        for key in keys:
            kv = self._store_pop(key)
            freed += kv[0].nbytes + kv[1].nbytes
            self.staged_keys.pop(key, None)
        self.n_trims += len(keys)
        return freed

    def release_exported(self, pages: Dict) -> int:
        """Free the accounting for an exported lane's pages when its
        snapshot is dropped without resuming (cancelled / shed work the
        scheduler abandoned) — the leak ``import_lane`` would otherwise
        never reclaim.  Returns bytes released."""
        freed = sum(entry[0][0].nbytes + entry[0][1].nbytes
                    for entry in pages.values())
        self.exported_bytes = max(0, self.exported_bytes - freed)
        return freed

    def begin_tick(self) -> None:
        """Reset the per-tick K/V dirty flag and the remap list; the
        engine calls this before a boundary-tick pass, pushes the pulled
        K/V back only when an install actually uploaded into it
        (metadata-only push otherwise), and executes `pending_remaps`
        device-side after the push."""
        self.kv_dirty = False
        self.pending_remaps = []

    def _free_slots(self, pt: np.ndarray, l: int, b: int,
                    lane_id: int) -> np.ndarray:
        """Free physical slots of (layer l, pool index b), excluding the
        lane's reserved staging slots — every allocator below goes through
        here so a staged page is never silently overwritten."""
        free = np.nonzero(pt[l, b] < 0)[0]
        reserved = self.stage_slots.get((l, lane_id))
        if reserved:
            free = free[~np.isin(free, reserved)]
        return free

    def tick(self, pool: dict, fstate: dict, step: int,
             reserve_slots: int = 1,
             lanes: Optional[Tuple[int, ...]] = None,
             lane_ids: Optional[Tuple[int, ...]] = None,
             thaw_lanes: Optional[Tuple[int, ...]] = None,
             keep_gids: Optional[Dict[int, Tuple[int, ...]]] = None,
             ) -> Tuple[dict, dict]:
        """pool: dict of numpy arrays {k, v, page_table, slot_mask};
        fstate: {c, d, frozen, frozen_at} (all (L, B, P) / page arrays).
        Decrements offloaded pages' timers, swaps out frozen device pages,
        swaps expired host pages back into free slots — keeping
        `reserve_slots` free for the incoming tail page (restores retry
        next step if the pool is contended).

        `lanes` restricts the pass to a subset of batch lanes (continuous
        batching ticks each lane at its own page-allocation cadence).
        `lane_ids` maps the pool's batch indices to global lane ids for the
        host-store keys — the serving engine transfers only the boundary
        lanes' pool slices, so index b of `pool` is lane `lane_ids[b]`.
        `thaw_lanes` (batch indices) are additionally serviced by
        ``thaw_lane`` after the timer pass — the entropy ladder's FR level
        raised ``thaw_request`` for them and their stashed pages come home
        ahead of their freeze timers; `keep_gids[b]` lists global page ids
        (tail + in-window) that must never be chosen as eviction victims."""
        from repro.serving.faults import StashAllocError
        k, v = pool["k"], pool["v"]
        pt, sm = pool["page_table"], pool["slot_mask"]
        L, B, P = pt.shape
        lane_set = range(B) if lanes is None else lanes
        frozen = fstate["frozen"]
        self.n_ticks += 1
        # 0) quantize resident frozen pages in place (kv_quant != "none"):
        # frozen pages are write-immutable, so this is the one moment a
        # page changes representation on device — before any swap-out, so
        # the store only ever receives the narrow payload
        self._quantize_frozen_resident(pool, fstate, lane_set)
        # ladder stage 2 (deepen): offloaded timers decrement on even
        # ticks only, so stashed pages stay out ~2x longer under pressure
        deepen_hold = self.deepen_timers and (self.n_ticks % 2 == 1)
        for l in range(L):
            for b in lane_set:
                gb = lane_ids[b] if lane_ids is not None else b
                # 1) swap out frozen device pages
                for p in range(P):
                    if pt[l, b, p] >= 0 and frozen[l, b, p]:
                        key = (l, gb, int(pt[l, b, p]))
                        kv_out, qm = self._store_payload(pool, l, b, p)
                        if self.stash_budget_bytes is not None \
                                and key not in self.store \
                                and self.stash_bytes + kv_out[0].nbytes \
                                    + kv_out[1].nbytes \
                                    > self.stash_budget_bytes:
                            # budget ceiling: the swap-out is the one
                            # stash producer that is pure optimization,
                            # so it is the rung that hard-stops at the
                            # budget — the page stays device-resident and
                            # frozen, and this swap-out retries once the
                            # ladder has drained some pressure
                            self.n_denied_offloads += 1
                            continue
                        try:
                            self._store_put(key, kv_out)
                        except StashAllocError:
                            # allocation failed: the page simply stays
                            # device-resident and frozen; this swap-out
                            # retries at the lane's next boundary tick
                            continue
                        if qm is not None:
                            self.quant_meta[key] = qm
                        self.frozen_meta[key] = {
                            "c": int(fstate["c"][l, b, p]),
                            "d": int(fstate["d"][l, b, p]),
                            "frozen_at": int(fstate["frozen_at"][l, b, p]),
                        }
                        pt[l, b, p] = -1
                        sm[l, b, p] = False
                        self._clear_quant_slot(pool, l, b, p)
                        for f in ("c", "d", "frozen", "frozen_at"):
                            fstate[f][l, b, p] = 0
                        self.n_swap_out += 1
                # 2) decrement offloaded timers; swap expired pages back in
                for key in sorted(self.frozen_meta):
                    kl, kb, gp = key
                    if kl != l or kb != gb:
                        continue
                    meta = self.frozen_meta[key]
                    if deepen_hold:
                        self.n_deepen_skips += 1
                        continue
                    meta["d"] -= 1
                    if meta["d"] <= 0:
                        free = self._free_slots(pt, l, b, gb)
                        if len(free) <= reserve_slots:
                            meta["d"] = 1          # retry next step
                            continue
                        p = int(free[0])
                        self._install_kv(pool, l, b, p, key)
                        pt[l, b, p] = gp
                        sm[l, b, p] = True
                        fstate["c"][l, b, p] = meta["c"]
                        del self.frozen_meta[key]
                        # keep host copy (pages are immutable once complete)
                        self.n_swap_in += 1
                        self._kv_transfer(l, gb, p, key)
        for b in (thaw_lanes or ()):
            gb = lane_ids[b] if lane_ids is not None else b
            self.thaw_lane(pool, fstate, b, gb,
                           keep_gids=(keep_gids or {}).get(b, ()),
                           reserve_slots=reserve_slots)
        for b in lane_set:
            gb = lane_ids[b] if lane_ids is not None else b
            self.refresh_resident_quant(pool, b, gb)
        return pool, fstate

    # ---- entropy-guided recovery: early thaw of stashed pages ---------- #
    def _evict_coldest(self, pool: dict, fstate: dict, l: int, b: int,
                       lane_id: int, keep_gids=(), skip_gids=()
                       ) -> Optional[int]:
        """Stash the coldest resident page of (layer, lane) to the host
        store and unmap its slot; returns the freed physical slot or None
        if nothing is evictable.  Coldness ranks frozen pages first, then
        ascending thaw priority (most-often-flagged, longest-frozen pages
        leave first).  The victim gets the forced-freeze timer (one
        page-fill interval) so it returns by itself; `keep_gids` (tail +
        in-window pages) and `skip_gids` (pages thawed in this very pass —
        prevents ping-pong) are never victims."""
        from repro.core.recovery import thaw_priority
        pt, sm = pool["page_table"], pool["slot_mask"]
        protected = set(keep_gids) | set(skip_gids)
        best, best_rank = None, None
        for p in range(pt.shape[2]):
            gid = int(pt[l, b, p])
            if gid < 0 or gid in protected:
                continue
            rank = (not bool(fstate["frozen"][l, b, p]),
                    thaw_priority(int(fstate["c"][l, b, p]),
                                  int(fstate["frozen_at"][l, b, p])), gid)
            if best_rank is None or rank < best_rank:
                best, best_rank = p, rank
        if best is None:
            return None
        gid = int(pt[l, b, best])
        key = (l, lane_id, gid)
        from repro.serving.faults import StashAllocError
        kv_out, qm = self._store_payload(pool, l, b, best)
        try:
            self._store_put(key, kv_out)
        except StashAllocError:
            # cannot stash the victim -> nothing is evictable right now;
            # callers already treat None as "pool stays as-is, retry later"
            return None
        if qm is not None:
            self.quant_meta[key] = qm
        self.frozen_meta[key] = {
            "c": max(int(fstate["c"][l, b, best]), 1),
            "d": self.cfg.freeze.page_size,
            "frozen_at": int(fstate["frozen_at"][l, b, best]),
        }
        pt[l, b, best] = -1
        sm[l, b, best] = False
        self._clear_quant_slot(pool, l, b, best)
        for f in ("c", "d", "frozen", "frozen_at"):
            fstate[f][l, b, best] = 0
        self.n_swap_out += 1
        return best

    def _install_page(self, pool: dict, fstate: dict, l: int, b: int,
                      p: int, key: Tuple[int, int, int]) -> bool:
        """Remap one stashed page into physical slot `p`, un-frozen (it
        re-enters attention and relevance accounting immediately);
        how the K/V reaches the device — host-bus upload or device-side
        copy from a staging slot — is ``_kv_transfer``'s call; metadata
        and the pulled host copy are identical either way.  A quantized
        page installs its narrow payload + scales verbatim (the kernel
        dequants at attention time — no host round-trip, and a staged
        remap stays remap-only).  Returns True when the install was
        remap-only (staged)."""
        meta = self.frozen_meta.pop(key)
        self._install_kv(pool, l, b, p, key)   # host copy stays (immutable)
        pool["page_table"][l, b, p] = key[2]
        pool["slot_mask"][l, b, p] = True
        fstate["c"][l, b, p] = meta["c"]
        fstate["d"][l, b, p] = 0
        fstate["frozen"][l, b, p] = False
        fstate["frozen_at"][l, b, p] = meta["frozen_at"]
        return self._kv_transfer(l, key[1], p, key)

    def _kv_transfer(self, l: int, lane_id: int, p: int,
                     key: Tuple[int, int, int]) -> bool:
        """Decide how target slot `p`'s K/V reaches the device.  Every
        install writes the *pulled host copy* (so later host-side reads
        this tick see real bytes); what differs is the device side: a
        page the engine staged gets a device-side copy staging-slot -> `p`
        queued in ``pending_remaps`` — no K/V crosses the host bus and the
        push stays metadata-only — while an unstaged page marks the pool
        K/V dirty so the push carries it.  The target slot is the caller's
        in both cases, so the pool layout (and every float summation
        order downstream) is identical whether or not the page was staged
        — the exact-parity guarantee of the async pipeline.  Returns True
        for a remap-only install."""
        src = self.staged_keys.pop(key, None)
        if src is not None and src in self.stage_slots.get((l, lane_id), []):
            self.pending_remaps.append((l, lane_id, src, p))
            self.n_remap_installs += 1
            return True
        self.kv_dirty = True
        self.n_upload_installs += 1
        return False

    def thaw_lane(self, pool: dict, fstate: dict, b: int, lane_id: int,
                  keep_gids=(), reserve_slots: int = 1,
                  max_pages: Optional[int] = None) -> int:
        """Entropy-guided recovery (FR level): remap the lane's stashed
        host pages back into its device pool ahead of their freeze timers.
        Candidates are ranked by ``recovery.thaw_priority`` over the freeze
        counters stashed with each page (fewest low-relevance flags, most
        recently frozen first).  A candidate the engine speculatively
        staged on device installs remap-only (``_kv_transfer`` queues a
        device-side copy — no K/V upload); otherwise, while free slots
        (beyond the tail reserve) exist they are used; once the pool is
        full the coldest
        resident page is evicted — stashed in turn with the forced-freeze
        timer — so the thaw trades the least-wanted resident page for the
        most-wanted stashed one.  Returns the number of pages thawed."""
        from repro.core.recovery import thaw_priority
        pt = pool["page_table"]
        L = pt.shape[0]
        # budget in *usable* pool slots — staging slots must not widen the
        # async arm's thaw pass relative to the sync arm's
        budget = self.max_active_pages if max_pages is None else max_pages
        thawed = 0
        for l in range(L):
            cand = [key for key in self.frozen_meta
                    if key[0] == l and key[1] == lane_id]
            # canonical tie-break: equal-priority candidates must rank
            # the same no matter the dict's insertion history — a lane
            # whose metas were rebuilt by ``import_lane`` (suspend/resume
            # migration) has to thaw the exact pages the uninterrupted
            # run would have
            cand.sort(key=lambda key: (-thaw_priority(
                self.frozen_meta[key]["c"],
                self.frozen_meta[key]["frozen_at"]), key))
            done_gids = []
            for key in cand[:budget]:
                free = self._free_slots(pt, l, b, lane_id)
                if len(free) > reserve_slots:
                    p = int(free[0])
                else:
                    p = self._evict_coldest(pool, fstate, l, b, lane_id,
                                            keep_gids=keep_gids,
                                            skip_gids=done_gids)
                    if p is None:
                        break
                if self._install_page(pool, fstate, l, b, p, key):
                    self.n_thaw_remap += 1
                else:
                    self.n_thaw_upload += 1
                done_gids.append(key[2])
                thawed += 1
                self.n_thaw += 1
        return thawed

    def ensure_resident(self, pool: dict, fstate: dict, b: int, lane_id: int,
                        gid: int, keep_gids=()) -> bool:
        """Make global page `gid` device-resident and un-frozen in every
        layer — the rewind path's requirement: the page holding the new
        tail position must be attendable and writable before decode
        resumes.  Resident-but-frozen copies are un-frozen in place;
        missing copies are thawed from the host store (evicting the
        coldest page if the pool is full).  A quantized copy is
        dequantized host-side here — uniquely among the thaw paths —
        because regeneration will *write into* this page (``write_tail``
        appends full-precision values), which a 1-byte payload cannot
        absorb.  Returns False only if a layer has neither a resident
        copy, a stashed copy, nor an evictable victim — the engine then
        skips the rewind."""
        pt = pool["page_table"]
        L = pt.shape[0]
        for l in range(L):
            where = np.nonzero(pt[l, b] == gid)[0]
            if len(where):
                p = int(where[0])
                fstate["frozen"][l, b, p] = False
                fstate["d"][l, b, p] = 0
                self._dequantize_resident(pool, l, b, p)
                continue
            key = (l, lane_id, gid)
            if key not in self.frozen_meta:
                return False
            free = self._free_slots(pt, l, b, lane_id)
            p = int(free[0]) if len(free) else \
                self._evict_coldest(pool, fstate, l, b, lane_id,
                                    keep_gids=keep_gids, skip_gids=(gid,))
            if p is None:
                return False
            remap = self._install_page(pool, fstate, l, b, p, key)
            if remap and self.quant_meta.get(key) is not None:
                # the staged device copy is the quantized payload, but the
                # rewind needs the writable full-precision page: cancel
                # the remap and let the push carry the dequantized bytes
                self.pending_remaps = [
                    r for r in self.pending_remaps
                    if r[:2] != (l, lane_id) or r[3] != p]
                remap = False
                self.kv_dirty = True
            if remap:
                self.n_thaw_remap += 1
            else:
                self.n_thaw_upload += 1
            self.n_thaw += 1
            self._dequantize_resident(pool, l, b, p)
        self.refresh_resident_quant(pool, b, lane_id)
        return True

    def _dequantize_resident(self, pool: dict, l: int, b: int,
                             p: int) -> None:
        """Host-side dequant of one resident pool page (rewind tail-page
        surgery): payload -> full precision in place, flag cleared."""
        from repro.core import quant
        pq = pool.get("page_quant")
        if pq is None or not pq[l, b, p]:
            return
        sc = pool["kv_scales"]
        pool["k"][l, b, p] = quant.dequantize_page(
            np.asarray(pool["k"][l, b, p]), np.asarray(sc[l, b, p, 0]))
        pool["v"][l, b, p] = quant.dequantize_page(
            np.asarray(pool["v"][l, b, p]), np.asarray(sc[l, b, p, 1]))
        self._clear_quant_slot(pool, l, b, p)
        self.kv_dirty = True

    def force_free_slot(self, pool: dict, fstate: dict, b: int, lane_id: int,
                        keep_gids=()) -> bool:
        """Guarantee at least one free physical slot per layer by evicting
        the coldest resident page wherever the pool is full — the tail
        allocator's backstop when recovery un-freezing left nothing for
        the timer-driven swap-out to release.  Returns False if a full
        layer has no evictable page."""
        pt = pool["page_table"]
        ok = True
        for l in range(pt.shape[0]):
            if len(self._free_slots(pt, l, b, lane_id)):
                continue
            ok &= self._evict_coldest(pool, fstate, l, b, lane_id,
                                      keep_gids=keep_gids) is not None
        return ok

    def alloc_tail(self, pool: dict, global_page: int) -> Optional[np.ndarray]:
        """Allocate a tail-page slot PER LAYER (layers' freeze patterns
        diverge, so their free slots do too; the jitted step takes an
        (L_attn,) tail_slot vector).  Slot must be free across the batch.
        Returns (L,) int32 or None if any layer's pool is full."""
        pt = pool["page_table"]
        L = pt.shape[0]
        slots = np.full((L,), -1, np.int32)
        for l in range(L):
            free = np.nonzero((pt[l] < 0).all(axis=0))[0]
            if len(free) == 0:
                return None
            slots[l] = free[0]
            pt[l, :, slots[l]] = global_page
        return slots

    # ---- per-lane bookkeeping (continuous batching) ------------------- #
    def alloc_tail_lane(self, pool: dict, lane: int, global_page: int,
                        lane_id: Optional[int] = None
                        ) -> Optional[np.ndarray]:
        """Allocate a tail-page slot per layer for ONE batch lane (other
        lanes' slots untouched); `lane_id` (default: same as `lane`) is
        the global lane whose staging slots must be skipped.  Returns
        (L,) int32 or None if full."""
        if lane_id is None:
            lane_id = lane
        pt = pool["page_table"]
        L = pt.shape[0]
        slots = np.full((L,), -1, np.int32)
        for l in range(L):
            free = self._free_slots(pt, l, lane, lane_id)
            if len(free) == 0:
                return None
            slots[l] = free[0]
            pt[l, lane, slots[l]] = global_page
        return slots

    def drop_lane(self, lane: int) -> int:
        """Forget every host-stored page belonging to one batch lane.

        Called on lane retirement/reassignment: the next occupant's pages
        must never collide with the retired request's global page ids.
        Returns the number of pages dropped."""
        stale = [key for key in self.store if key[1] == lane]
        for key in stale:
            self._store_pop(key)
            self.frozen_meta.pop(key, None)
            self.staged_keys.pop(key, None)
        self.resident_quant.pop(lane, None)   # device-savings gauge entry
        return len(stale)

    # ---- whole-lane stash/restore (scheduler preemption) -------------- #
    def export_lane(self, lane: int) -> Dict[Tuple[int, int],
                                             Tuple[Tuple[np.ndarray,
                                                         np.ndarray],
                                                   Optional[Dict[str, int]],
                                                   Optional[Tuple]]]:
        """Move every host-store entry of one lane OUT of the controller:
        returns ``{(layer, gid): ((k, v), frozen_meta-or-None,
        quant_scales-or-None)}`` and forgets the keys.  This is the
        suspend path of lane preemption — the pages must survive the lane
        being reassigned (``write_lane`` / ``drop_lane`` would otherwise
        delete them with the old occupant's) and come back under a
        possibly *different* lane id.  Entries without ``frozen_meta``
        are the immutable host copies of device-resident pages; they
        transfer too, so a resumed lane's swap-out path keeps its
        no-recopy invariant.  Quantized payloads travel AS-IS (narrow
        bytes + scales) — a suspend/resume cycle never re-quantizes.
        The page's speculative staging slot (``staged_keys``) rides along
        as the 4th element: the slot index is lane-relative to the shared
        ``[P, P_total)`` staging range, so the resume destination can
        re-upload the page and keep the thaw-remap schedule — and with it
        any entropy-triggered Rewalk — exactly on the uninterrupted run's
        path."""
        out = {}
        for key in [k for k in self.store if k[1] == lane]:
            qm = self.quant_meta.get(key)
            kv = self._store_pop(key)
            meta = self.frozen_meta.pop(key, None)
            staged = self.staged_keys.pop(key, None)
            out[(key[0], key[2])] = (kv, meta, qm, staged)
            self.exported_bytes += kv[0].nbytes + kv[1].nbytes
        return out

    def copy_lane(self, lane: int) -> Dict[Tuple[int, int], Tuple]:
        """Checkpoint variant of ``export_lane``: the same mapping, but
        the controller keeps its entries and no accounting moves — the
        caller gets a consistent point-in-time view for an off-engine
        mirror.  Freeze metas are copied (timers mutate in place); the
        page payloads are shared (store pages are immutable by
        convention — every mutation path re-``_store_put``s a fresh
        array)."""
        out = {}
        for key in [k for k in self.store if k[1] == lane]:
            meta = self.frozen_meta.get(key)
            out[(key[0], key[2])] = (
                self.store[key],
                dict(meta) if meta is not None else None,
                self.quant_meta.get(key),
                self.staged_keys.get(key))
        return out

    def import_lane(self, lane: int, pages: Dict,
                    counted: bool = True) -> None:
        """Inverse of ``export_lane``, rekeyed to ``lane`` (the resume
        destination — not necessarily the lane the pages left).  Freeze
        timers resume exactly where they stopped: a suspended lane has no
        page-boundary ticks, so no decrements were missed.  Accepts
        legacy 3-tuples (no staged slot) alongside 4-tuples.
        ``counted=False`` skips the ``exported_bytes`` decrement — for
        checkpoint snapshots (``copy_lane``) whose bytes were never
        moved out of the controller's accounting."""
        for (layer, gid), entry in pages.items():
            kv, meta, qm = entry[0], entry[1], entry[2]
            staged = entry[3] if len(entry) > 3 else None
            key = (layer, lane, gid)
            # unguarded: the bytes already exist (moving back from the
            # snapshot's accounting) and a resume must never fail
            self._store_put(key, kv, guarded=False)
            if counted:
                self.exported_bytes = max(
                    0, self.exported_bytes - (kv[0].nbytes + kv[1].nbytes))
            if meta is not None:
                self.frozen_meta[key] = dict(meta)
            if qm is not None:
                self.quant_meta[key] = qm
            if staged is not None:
                self.staged_keys[key] = staged

    def drop_pages_from(self, lane: int, first_gid: int) -> int:
        """Forget the host copies of one lane's pages with global id >=
        `first_gid` — the Rewalk-rewind path: pages wholly past the rewind
        point are regenerated, so a stashed copy of the rewound generation
        must never swap back in over the replayed pages.  Returns the
        number of pages dropped."""
        stale = [key for key in self.store
                 if key[1] == lane and key[2] >= first_gid]
        for key in stale:
            self._store_pop(key)
            self.frozen_meta.pop(key, None)
            self.staged_keys.pop(key, None)
        return len(stale)

    def stash(self, layer: int, lane: int, global_page: int,
              k: np.ndarray, v: np.ndarray, d: int) -> None:
        """Place one page straight into the host store with freeze timer
        `d` — the admission path for prompt pages that exceed the device
        pool (chunked-prefill overflow uses the forced-freeze timer).
        A ``StashAllocError`` propagates: admission overflow has no
        device-side fallback (the pool is full by definition), so this is
        the one unsurvivable stash fault — callers admit the request only
        once the stash can hold its overflow."""
        from repro.core import quant
        key = (layer, lane, global_page)
        mode = self.quant_mode
        if mode:
            pk, sk = quant.quantize_page(np.asarray(k), mode)
            pv, sv = quant.quantize_page(np.asarray(v), mode)
            self._store_put(key, (pk, pv))
            self.quant_meta[key] = (sk, sv)
            self.n_quantized_pages += 1
        else:
            self._store_put(key, (k.copy(), v.copy()))
        self.frozen_meta[key] = {"c": 1, "d": int(d), "frozen_at": 0}
        self.n_swap_out += 1

    def write_lane(self, pool: dict, fstate: dict, lane: int,
                   k_resident: np.ndarray,    # (L, n, page, KVH, hd)
                   v_resident: np.ndarray,
                   page_ids: np.ndarray,      # (n,) global ids
                   slot_masks: np.ndarray,    # (n, page) bool
                   store_lane: Optional[int] = None,
                   ) -> np.ndarray:
        """Wholesale-reset one lane's device pages and install `n` resident
        pages into its first slots — admission after a (chunked) prefill.
        Neighbouring lanes' slots, tables and freeze state are untouched.
        `lane` indexes the pool arrays; `store_lane` (default: same) is the
        global lane id whose host store is dropped — they differ when the
        engine hands over a single-lane pool slice.
        Returns the (L, n) physical slots used (slot i holds page_ids[i] in
        every layer, so the engine's per-layer tail slots start aligned)."""
        k, v = pool["k"], pool["v"]
        pt, sm = pool["page_table"], pool["slot_mask"]
        L, B, P = pt.shape
        n = len(page_ids)
        assert n <= P, (n, P)
        self.drop_lane(lane if store_lane is None else store_lane)
        pt[:, lane, :] = -1
        sm[:, lane, :] = False
        k[:, lane] = 0
        v[:, lane] = 0
        if "page_quant" in pool:          # fresh occupant: all pages hot
            pool["page_quant"][:, lane] = 0
            pool["kv_scales"][:, lane] = 1.0
        self.resident_quant.pop(
            lane if store_lane is None else store_lane, None)
        for f in ("c", "d", "frozen", "frozen_at"):
            fstate[f][:, lane] = 0
        slots = np.zeros((L, n), np.int32)
        for l in range(L):
            for i in range(n):
                k[l, lane, i] = k_resident[l, i]
                v[l, lane, i] = v_resident[l, i]
                pt[l, lane, i] = page_ids[i]
                sm[l, lane, i] = slot_masks[i]
                slots[l, i] = i
        return slots

    def host_bytes(self) -> int:
        return sum(kk.nbytes + vv.nbytes for kk, vv in self.store.values())

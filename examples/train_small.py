"""End-to-end training driver: train a ~20M-param llama-family model on the
synthetic LM pipeline for a few hundred steps with AdamW + checkpointing.
(CPU container scale; on TPU the same driver scales via launch/train.py.)

    PYTHONPATH=src python examples/train_small.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.training import checkpoint as CKPT
from repro.training import data as DATA
from repro.training import train_step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="experiments/train_small.msgpack")
    args = ap.parse_args()

    cfg = get_config("llama3-8b-tiny")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=256, num_heads=4,
                              num_kv_heads=2, head_dim=64, d_ff=512,
                              vocab_size=512, dtype="float32")
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"params: {n/1e6:.1f}M  steps: {args.steps}")

    step_fn = jax.jit(lambda s, b: TS.train_step(s, b, cfg, lr=1e-3))
    it = DATA.synthetic_lm(DATA.DataConfig(cfg.vocab_size, args.seq,
                                           args.batch, seed=0))
    t0, losses = time.time(), []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.3f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"{(time.time()-t0)/(i+1):.2f}s/step")
    CKPT.save(args.ckpt, state.params)
    print(f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}; "
          f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()

"""Passkey retrieval through a frozen cache (paper §4.3, Table 2), plus the
bounded-active paged long-context mode.

Protocol (CPU-scale, untrained-weights honest version): the decisive test is
*retrieval parity* — greedy decode with ASR-KF-EGR ON must reproduce the
full-KV baseline's greedy continuation after the passkey query, proving the
freeze mechanism lost no information the baseline had.  (The paper's
absolute-digit PASS additionally needs a trained retriever model — see
benchmarks/table2 which trains an induction model first.)

    PYTHONPATH=src python examples/longcontext_passkey.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams
from repro.training import data as DATA


def main():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, window=16, tau_mode="quantile",
                             quantile=0.45, k_soft=2.0,
                             recovery_enabled=True,
                             entropy_abs_threshold=1e9)  # relative-only spikes
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)

    passkey = 44181                                # the paper's Table 2 key
    ctx = 384
    prompt, needle_pos = DATA.passkey_prompt(cfg.vocab_size, ctx, passkey,
                                             seed=7)
    batch = {"tokens": jnp.asarray(prompt[None])}

    outs = {}
    for label, freeze in (("baseline", False), ("asr-kf-egr", True)):
        eng = Engine(cfg, params, max_seq=ctx + 32, enable_freeze=freeze)
        res = eng.generate(batch, DATA.N_DIGITS + 3, SamplingParams.greedy())
        outs[label] = res
        comp = 100 * res.compression
        print(f"{label:12s}: tokens {res.tokens[0].tolist()}  "
              f"compression {comp:.1f}%")

    parity = bool((outs["baseline"].tokens == outs["asr-kf-egr"].tokens).all())
    print(f"\nretrieval parity (greedy, frozen vs full KV): "
          f"{'PASS' if parity else 'DIVERGED'}")
    needle = DATA.encode_passkey(passkey)
    got = outs["asr-kf-egr"].tokens[0][: DATA.N_DIGITS]
    verdict = "PASS" if (got == needle).all() \
        else "needs trained model — see benchmarks table2"
    print(f"needle tokens {needle.tolist()} -> generated {got.tolist()} "
          f"({verdict})")


if __name__ == "__main__":
    main()

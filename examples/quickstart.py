"""Quickstart: build a tiny model, generate with ASR-KF-EGR freeze
management on, and inspect the compression telemetry.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, list_archs
from repro.models import model as MD
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list_archs())
    ap.add_argument("--tokens", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-tiny")   # reduced variant for CPU
    # quantile-tau (beyond-paper) so compression is scale-invariant on an
    # untrained model; paper mode would be tau_mode="fixed", tau=0.5
    fc = dataclasses.replace(cfg.freeze, window=16, tau_mode="quantile",
                             quantile=0.45, k_soft=1.0, page_size=16,
                             recovery_enabled=True,
                             entropy_abs_threshold=1e9)  # relative-only spikes
    cfg = dataclasses.replace(cfg, freeze=fc)
    print(f"arch={cfg.name}  layers={cfg.num_layers} d_model={cfg.d_model}")

    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_seq=args.tokens + 64)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                cfg.vocab_size)
    res = eng.generate({"tokens": prompt}, args.tokens,
                       SamplingParams(temperature=0.7, top_k=40, top_p=0.9))

    print(f"generated {res.tokens.shape[1]} tokens")
    print(f"active KV at end : {res.active_kv[-1]:.0f} / {res.total_kv[-1]}")
    print(f"compression      : {100 * res.compression:.1f}%  "
          f"(paper reports 55-67% on LLaMA-3 8B)")
    print(f"host-offloaded   : {res.offloaded_tokens[-1]} tokens")
    print(f"recovery events  : {len(res.recovery_events)}   "
          f"rewinds: {res.rewinds}")
    # ASCII trajectory (paper Fig. 1)
    traj = res.active_kv[:: max(1, len(res.active_kv) // 60)]
    mx = max(traj)
    print("\nactive-KV trajectory (paper Fig. 1 analogue):")
    for h in range(8, 0, -1):
        row = "".join("#" if a / mx >= h / 8 else " " for a in traj)
        print(f"{mx * h / 8:6.0f} |{row}")
    print("       " + "-" * len(traj))


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper is an inference paper, so this is
the primary E2E example): serve a mixed-length request trace through the
continuous-batching Scheduler with ASR-KF-EGR freeze management, comparing
three arms — full-KV static baseline, ASR-KF-EGR static, and ASR-KF-EGR
continuous — the paper's Table 1 protocol at example scale plus the serving
upgrade on top.

    PYTHONPATH=src python examples/serve_freeze.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import ContinuousEngine, Engine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler, StaticScheduler


def main():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, window=16, tau_mode="quantile",
                             quantile=0.45, k_soft=1.0, page_size=16,
                             entropy_abs_threshold=1e9)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    # mixed-length trace: short requests co-batched with long ones is
    # exactly where continuous batching wins
    trace = [(rng.randint(0, cfg.vocab_size, size=rng.randint(16, 48)), n)
             for n in (160, 40, 40, 40, 80, 60, 40, 40)]

    def submit_all(sched):
        for prompt, n in trace:
            sched.submit(prompt, n_tokens=n,
                         sampling=SamplingParams(temperature=0.7))

    arms = (
        ("full-KV static", lambda: StaticScheduler(
            Engine(cfg, params, max_seq=512, enable_freeze=False),
            batch_size=4)),
        ("ASR-KF-EGR static", lambda: StaticScheduler(
            Engine(cfg, params, max_seq=512), batch_size=4)),
        ("ASR-KF-EGR continuous", lambda: Scheduler(
            ContinuousEngine(cfg, params, max_seq=512, n_lanes=4))),
    )
    for label, mk in arms:
        sched = mk()
        submit_all(sched)
        t0 = time.time()
        sched.run()
        dt = time.time() - t0
        total = sum(len(r.result) for r in sched.done.values())
        extra = ""
        if isinstance(sched, Scheduler):
            eng = sched.engine
            # first tokens come from prefill, not decode lane-steps
            util = 100 * (total - len(sched.done)) \
                / (eng.wall_step * eng.n_lanes)
            extra = f", {eng.wall_step} steps, {util:.0f}% lane utilization"
        print(f"{label:22s}: {len(sched.done)} requests, {total} tokens, "
              f"{dt:.1f}s ({1e3 * dt / total:.1f} ms/token){extra}")

    # detailed per-request freeze telemetry from the continuous engine
    eng = ContinuousEngine(cfg, params, max_seq=512, n_lanes=4)
    sched = Scheduler(eng)
    submit_all(sched)
    sched.run()
    res = sched.done[1].telemetry          # the longest request
    print(f"\nASR-KF-EGR telemetry (request 1, {len(res.tokens[0])} tokens):")
    print(f"  compression        : {100 * res.compression:.1f}%")
    print(f"  mean active KV     : {np.mean(res.active_kv):.0f}")
    print(f"  host-offloaded     : {max(res.offloaded_tokens)} tokens peak")
    print(f"  recovery events    : {len(res.recovery_events)}")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper is an inference paper, so this is
the primary E2E example): serve a small model with batched requests through
the Scheduler with ASR-KF-EGR freeze management, and compare against the
full-KV baseline — the paper's Table 1 protocol at example scale.

    PYTHONPATH=src python examples/serve_freeze.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler


def main():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, window=16, tau_mode="quantile",
                             quantile=0.45, k_soft=1.0, page_size=16,
                             entropy_abs_threshold=1e9)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)

    for label, freeze in (("full-KV baseline", False), ("ASR-KF-EGR", True)):
        eng = Engine(cfg, params, max_seq=512, enable_freeze=freeze)
        sched = Scheduler(eng, batch_size=4)
        for _ in range(8):                      # 8 requests, 2 batches
            prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(16, 48))
            sched.submit(prompt, n_tokens=160,
                         sampling=SamplingParams(temperature=0.7))
        t0 = time.time()
        sched.run()
        dt = time.time() - t0
        total = sum(len(r.result) for r in sched.done.values())
        # last engine result telemetry
        print(f"{label:18s}: {len(sched.done)} requests, {total} tokens, "
              f"{dt:.1f}s ({1e3 * dt / total:.1f} ms/token)")
        if freeze:
            res = None
    # detailed freeze stats from one fresh batched run
    eng = Engine(cfg, params, max_seq=512)
    toks = rng.randint(0, cfg.vocab_size, size=(4, 32)).astype(np.int32)
    import jax.numpy as jnp
    res = eng.generate({"tokens": jnp.asarray(toks)}, 200)
    print(f"\nASR-KF-EGR telemetry (batch=4, 200 tokens):")
    print(f"  compression        : {100 * res.compression:.1f}%")
    print(f"  mean active KV     : {np.mean(res.active_kv):.0f}")
    print(f"  host-offloaded     : {max(res.offloaded_tokens)} tokens peak")
    print(f"  recovery events    : {len(res.recovery_events)}")


if __name__ == "__main__":
    main()
